"""Grouped ragged-M GEMM: kernel parity/properties + capture routing.

Kernel level: the Pallas path (interpret=True on CPU; same code targets
TPU) and the ops wrapper (padding, tile selection, ref fallback) against
the pure-jnp oracle over random ragged group sizes — zero-row groups
included.  Capture level: a wave of same-(K, F) matmul branches with
unequal M must lower to ONE ``grouped_gemm`` step whose outputs match
naive sequential execution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OpGraph,
    OpKind,
    compile_plan,
    run_sequential_uncompiled,
    schedule,
)
from repro.core.profiler import gemm_cost
from repro.kernels.grouped_gemm.kernel import grouped_gemm_pallas
from repro.kernels.grouped_gemm.ops import grouped_gemm
from repro.kernels.grouped_gemm.ref import grouped_gemm_ref

rng = np.random.default_rng(0)


def _rand(shape, dtype, scale=0.1):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _assert_close(a, b, rtol, atol):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=rtol, atol=atol)


# ------------------------------------------------------------ kernel parity

@pytest.mark.parametrize("sizes", [(8, 16, 24), (8, 0, 16), (0, 8, 0, 32),
                                   (40,), (1, 2, 3, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_gemm_matches_ref(sizes, dtype):
    k, f = 128, 128
    x = _rand((sum(sizes), k), dtype)
    w = _rand((len(sizes), k, f), dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    _assert_close(grouped_gemm(x, w, sizes), grouped_gemm_ref(x, w, sizes),
                  tol, tol)


def test_grouped_gemm_random_property():
    """Random ragged splits (zero-row groups included) against the oracle —
    and against ``jax.lax.ragged_dot`` where this jax version has it."""
    prng = np.random.default_rng(7)
    for _ in range(5):
        n = int(prng.integers(1, 6))
        sizes = tuple(int(prng.integers(0, 20)) for _ in range(n))
        k, f = 128, 256
        x = jnp.asarray(prng.standard_normal((sum(sizes), k)) * 0.1,
                        jnp.float32)
        w = jnp.asarray(prng.standard_normal((n, k, f)) * 0.1, jnp.float32)
        got = grouped_gemm(x, w, sizes)
        _assert_close(got, grouped_gemm_ref(x, w, sizes), 1e-5, 1e-5)
        if hasattr(jax.lax, "ragged_dot") and sum(sizes):
            rd = jax.lax.ragged_dot(x, w, jnp.asarray(sizes, jnp.int32))
            _assert_close(got, rd, 1e-5, 1e-5)


def test_grouped_gemm_pallas_direct():
    """The kernel itself (pre-padded layout, explicit tile→group table)."""
    bm, k, f = 8, 128, 128
    sizes = (16, 8, 24)                       # already bm multiples
    tile_group = (0, 0, 1, 2, 2, 2)
    x = _rand((sum(sizes), k), jnp.float32)
    w = _rand((len(sizes), k, f), jnp.float32)
    got = grouped_gemm_pallas(x, w, tile_group, bm=bm, bf=128, bk=128,
                              interpret=True)
    _assert_close(got, grouped_gemm_ref(x, w, sizes), 1e-5, 1e-5)


def test_grouped_gemm_non_tileable_falls_back_to_ref():
    """K/F off the 128 lattice → einsum reference, numerics unchanged."""
    sizes = (3, 7, 5)
    x = _rand((sum(sizes), 48), jnp.float32)
    w = _rand((len(sizes), 48, 80), jnp.float32)
    got = grouped_gemm(x, w, sizes)
    _assert_close(got, grouped_gemm_ref(x, w, sizes), 1e-5, 1e-5)


def test_grouped_gemm_all_empty():
    x = jnp.zeros((0, 128), jnp.float32)
    w = _rand((3, 128, 128), jnp.float32)
    assert grouped_gemm(x, w, (0, 0, 0)).shape == (0, 128)


def test_grouped_gemm_validates_inputs():
    x = jnp.zeros((10, 128), jnp.float32)
    w = jnp.zeros((2, 128, 128), jnp.float32)
    with pytest.raises(ValueError, match="group sizes"):
        grouped_gemm(x, w, (10,))
    with pytest.raises(ValueError, match="sum_M"):
        grouped_gemm(x, w, (4, 4))
    with pytest.raises(ValueError, match="negative"):
        grouped_gemm(x, w, (12, -2))


# ---------------------------------------------------------- capture routing

def _mm(x, w):
    return x @ w


def _mm_b(x, w, b):
    return x @ w + b


def build_ragged_graph(sizes, k=128, f=128, dtype=jnp.float32,
                       bias=False, seed=3):
    """N parallel matmul branches sharing (K, F) with unequal M — the MoE
    expert fan-out shape, hand-built."""
    prng = np.random.default_rng(seed)
    g = OpGraph("ragged")
    for i, m in enumerate(sizes):
        x = g.add(f"x{i}", OpKind.INPUT, out_shape=(m, k), out_dtype=dtype)
        w = jnp.asarray(prng.standard_normal((k, f)) * 0.05, dtype)
        consts = (w,)
        if bias:
            consts += (jnp.asarray(prng.standard_normal((f,)), dtype),)
        g.add(f"gemm{i}", OpKind.GEMM, [x],
              fn=_mm_b if bias else _mm, cost=gemm_cost(m, k, f, 4),
              fuse_sig=("gemm", k, f, bias), consts=consts,
              payload="matmul", out_shape=(m, f), out_dtype=dtype)
    g.validate()
    return g


def _inputs_for(g, seed=9):
    prng = np.random.default_rng(seed)
    return {n.name: jnp.asarray(
                prng.standard_normal(n.out_shape) * 0.1, n.out_dtype)
            for n in g if n.fn is None}


@pytest.mark.parametrize("bias", [False, True])
def test_capture_routes_ragged_group_to_grouped_gemm(bias):
    sizes = (8, 24, 16)
    g = build_ragged_graph(sizes, bias=bias)
    exe = compile_plan(schedule(g, "opara", "opara"))
    stats = exe.program_stats()
    assert stats["n_grouped_gemm"] == 1, stats
    step = next(s for s in exe.steps if s.route == "grouped_gemm")
    # the offset table follows the packed branch order within the wave
    assert step.group_sizes == tuple(
        g.nodes[g.nodes[op].inputs[0]].out_shape[0] for op in step.op_ids)
    assert sorted(step.group_sizes) == sorted(sizes)
    inputs = _inputs_for(g)
    got = exe(inputs)
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe.output_ids)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_capture_ragged_vmap_kernel_falls_back_to_singles():
    """gemm_kernel="vmap" cannot stack ragged branches — per-branch calls,
    same numerics."""
    g = build_ragged_graph((8, 24, 16))
    plan = schedule(g, "opara", "opara")
    exe = compile_plan(plan, gemm_kernel="vmap")
    stats = exe.program_stats()
    assert stats["n_grouped_gemm"] == 0 and stats["n_vmap"] == 0
    inputs = _inputs_for(g)
    got = exe(inputs)
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe.output_ids)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_capture_ragged_non_tileable_still_one_step():
    """Ragged group on off-lattice (K, F): still ONE grouped step — the ops
    wrapper's ref fallback keeps it fused."""
    g = build_ragged_graph((3, 5, 9), k=48, f=80)
    exe = compile_plan(schedule(g, "opara", "opara"))
    assert exe.program_stats()["n_grouped_gemm"] == 1
    inputs = _inputs_for(g)
    got = exe(inputs)
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe.output_ids)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_capture_equal_sizes_keep_stacked_path():
    """Uniform M with declared shapes must NOT take the grouped route — the
    stacked (branch_gemm/vmap) path is strictly cheaper."""
    g = build_ragged_graph((16, 16, 16))
    exe = compile_plan(schedule(g, "opara", "opara"))
    stats = exe.program_stats()
    assert stats["n_grouped_gemm"] == 0
    assert stats["n_branch_gemm"] + stats["n_vmap"] == 1
