"""Paged decode attention: Pallas kernel parity vs the gather-einsum ref,
vs dense decode attention, the MLA absorbed variant, and the structured
fallback ladder recording."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_decode.kernel import paged_decode_attention_pallas
from repro.kernels.paged_decode.ops import (paged_decode_attention,
                                            paged_mla_decode_attention)
from repro.kernels.paged_decode.ref import paged_decode_attention_ref
from repro.runtime.guard import kernel_log

# on-lattice interpret-mode geometry: grid = b*h*maxp = 2*4*2 = 16 <= limit
B, H, KVH, DK, DV, PS, NPAGES, MAXP = 2, 4, 2, 8, 8, 128, 6, 2


def _rand(seed=0, dtype=jnp.float32, kvh=KVH, dk=DK, dv=DV, ps=PS):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, dk), dtype)
    k = jax.random.normal(ks[1], (NPAGES, ps, kvh, dk), dtype)
    v = jax.random.normal(ks[2], (NPAGES, ps, kvh, dv), dtype)
    bt = jnp.array([[1, 3], [2, 5]], jnp.int32)
    return q, k, v, bt


def test_pallas_matches_ref():
    q, k, v, bt = _rand()
    lengths = jnp.array([2 * PS - 40, PS + 3], jnp.int32)   # ragged
    ref = paged_decode_attention_ref(q, k, v, bt, lengths)
    out = paged_decode_attention_pallas(
        q, jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), bt.reshape(-1),
        jnp.zeros_like(lengths), lengths, scale=float(DK ** -0.5),
        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_pallas_windowed_starts():
    q, k, v, bt = _rand(seed=1)
    lengths = jnp.array([2 * PS, PS + 60], jnp.int32)
    starts = jnp.array([PS + 10, 17], jnp.int32)            # window lower bound
    ref = paged_decode_attention_ref(q, k, v, bt, lengths, starts)
    out = paged_decode_attention_pallas(
        q, jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), bt.reshape(-1),
        starts, lengths, scale=float(DK ** -0.5), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)
    # a fully-masked LEADING page must not poison the online softmax
    starts2 = jnp.array([PS + 10, PS], jnp.int32)
    ref2 = paged_decode_attention_ref(q, k, v, bt, lengths, starts2)
    out2 = paged_decode_attention_pallas(
        q, jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), bt.reshape(-1),
        starts2, lengths, scale=float(DK ** -0.5), interpret=True)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref2),
                               atol=2e-6, rtol=2e-6)


def test_wrapper_routes_pallas_on_lattice():
    q, k, v, bt = _rand(seed=2)
    lengths = jnp.array([100, 200], jnp.int32)
    before = kernel_log().count("paged_decode")
    out = paged_decode_attention(q, k, v, bt, lengths)
    ref = paged_decode_attention_ref(q, k, v, bt, lengths,
                                     jnp.zeros_like(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)
    assert kernel_log().count("paged_decode") == before   # no fallback fired


def test_paged_matches_dense_decode_attention():
    """Gathering the pages into a dense cache and running the dense decode
    kernel must agree with attending through the block table."""
    from repro.kernels.decode_attention.ref import decode_attention_ref

    q, k, v, bt = _rand(seed=3)
    lengths = jnp.array([2 * PS, PS + 31], jnp.int32)
    paged = paged_decode_attention_ref(q, k, v, bt, lengths)
    kd = k[bt].reshape(B, MAXP * PS, KVH, DK)         # dense gather
    vd = v[bt].reshape(B, MAXP * PS, KVH, DV)
    valid = jnp.arange(MAXP * PS)[None, :] < lengths[:, None]
    dense = decode_attention_ref(q, jnp.swapaxes(kd, 1, 2),
                                 jnp.swapaxes(vd, 1, 2), valid)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


def test_bf16_parity_loose():
    q, k, v, bt = _rand(seed=4, dtype=jnp.bfloat16)
    lengths = jnp.array([2 * PS - 5, PS], jnp.int32)
    ref = paged_decode_attention_ref(q, k, v, bt, lengths)
    out = paged_decode_attention_pallas(
        q, jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), bt.reshape(-1),
        jnp.zeros_like(lengths), lengths, scale=float(DK ** -0.5),
        interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_mla_variant_matches_manual_absorption():
    rank, rope, nope = 16, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    qn = jax.random.normal(ks[0], (B, H, nope), jnp.float32)
    qp = jax.random.normal(ks[1], (B, H, rope), jnp.float32)
    ckv = jax.random.normal(ks[2], (NPAGES, PS, rank), jnp.float32)
    kpe = jax.random.normal(ks[3], (NPAGES, PS, rope), jnp.float32)
    wkb = jax.random.normal(ks[4], (rank, H, nope), jnp.float32)
    bt = jnp.array([[1, 2], [3, 4]], jnp.int32)
    lengths = jnp.array([2 * PS, PS + 9], jnp.int32)
    scale = (nope + rope) ** -0.5
    out = paged_mla_decode_attention(qn, qp, ckv, kpe, wkb, bt, lengths, scale)
    assert out.shape == (B, H, rank)
    q_lat = jnp.einsum("bhd,rhd->bhr", qn, wkb,
                       preferred_element_type=jnp.float32).astype(qn.dtype)
    q_cat = jnp.concatenate([q_lat, qp], axis=-1)
    k_cat = jnp.concatenate([ckv, kpe], axis=-1)[:, :, None, :]
    ref = paged_decode_attention_ref(q_cat, k_cat, ckv[:, :, None, :], bt,
                                     lengths, None, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_off_lattice_fallback_is_recorded():
    """ps % 128 != 0 routes to the ref AND lands on the kernel ladder log."""
    ps = 16
    q, k, v, bt = _rand(seed=5, ps=ps)
    lengths = jnp.array([20, 30], jnp.int32)
    before = kernel_log().count("paged_decode")
    out = paged_decode_attention(q, k, v, bt, lengths)
    assert kernel_log().count("paged_decode") == before + 1
    ev = [e for e in kernel_log().events if e.site == "paged_decode"][-1]
    assert ev.action == "pallas->ref"
    assert "off-lattice" in ev.reason
    ref = paged_decode_attention_ref(q, k, v, bt, lengths,
                                     jnp.zeros_like(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


def test_dense_decode_off_lattice_fallback_recorded():
    """The pre-existing silent dense decode fallback (t%128 / d%8) now
    reports through the kernel ladder log."""
    from repro.kernels.decode_attention.ops import decode_attention

    b, h, t, d = 2, 4, 48, 8                             # t % 128 != 0
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, t, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, t, d), jnp.float32)
    valid = jnp.ones((b, t), bool)
    before = kernel_log().count("decode_attention")
    decode_attention(q, k, v, valid)
    assert kernel_log().count("decode_attention") == before + 1
    ev = [e for e in kernel_log().events
          if e.site == "decode_attention"][-1]
    assert ev.action == "pallas->ref" and "off-lattice" in ev.reason


def test_interpret_grid_guard_routes_ref_silently():
    """Above INTERPRET_GRID_LIMIT the wrapper uses the ref without a
    degradation event (a route decision, not a failure)."""
    from repro.kernels import INTERPRET_GRID_LIMIT

    maxp = INTERPRET_GRID_LIMIT // (B * H) + 1
    npages = maxp + 1
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (B, H, DK), jnp.float32)
    k = jax.random.normal(ks[1], (npages, PS, KVH, DK), jnp.float32)
    v = jax.random.normal(ks[2], (npages, PS, KVH, DV), jnp.float32)
    bt = jnp.broadcast_to(jnp.arange(1, maxp + 1, dtype=jnp.int32)[None],
                          (B, maxp))
    lengths = jnp.array([maxp * PS, PS], jnp.int32)
    before = kernel_log().count("paged_decode")
    out = paged_decode_attention(q, k, v, bt, lengths)
    assert kernel_log().count("paged_decode") == before
    ref = paged_decode_attention_ref(q, k, v, bt, lengths,
                                     jnp.zeros_like(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))
