"""KV page pool: deterministic allocation, ref-count/COW correctness,
per-tenant accounting, exhaustion semantics, content-key chaining."""
import numpy as np
import pytest

from repro.serving.kv_pool import (KVPagePool, KVPoolConfig, PageExhausted,
                                   page_content_keys)


def mk(num_pages=9, page_size=4):
    return KVPagePool(KVPoolConfig(num_pages=num_pages, page_size=page_size))


def test_null_page_reserved_and_lowest_first():
    pool = mk()
    table = pool.ensure("a", 9)          # 3 pages of 4 positions
    assert table == [1, 2, 3]            # page 0 never handed out; min-heap order
    assert pool.used_pages == 3
    assert pool.free_pages == 5


def test_ensure_is_incremental_and_idempotent():
    pool = mk()
    assert pool.ensure("a", 3) == [1]
    assert pool.ensure("a", 4) == [1]    # still fits one page
    assert pool.ensure("a", 5) == [1, 2]
    assert pool.stats["allocs"] == 2


def test_release_returns_pages_in_order():
    pool = mk()
    pool.ensure("a", 8)                  # pages 1,2
    pool.ensure("b", 4)                  # page 3
    assert pool.release("a") == 2
    assert not pool.holds("a")
    # freed pages are reused lowest-first: deterministic replay
    assert pool.ensure("c", 8) == [1, 2]
    assert pool.release("c") == 2
    assert pool.release("b") == 1
    assert pool.used_pages == 0


def test_double_free_is_hard_error():
    pool = mk()
    pool.ensure("a", 4)
    assert pool.release("a") == 1
    assert pool.release("a") == 0        # re-release of a dropped rid: no-op
    with pytest.raises(RuntimeError, match="double free"):
        pool._decref(1)                  # freeing an already-free page


def test_all_or_nothing_exhaustion():
    pool = mk(num_pages=4)               # 3 usable pages
    pool.ensure("a", 8)                  # 2 pages
    with pytest.raises(PageExhausted):
        pool.ensure("b", 8)              # needs 2, only 1 free
    # nothing was allocated for b — no half-mapped request
    assert not pool.holds("b")
    assert pool.free_pages == 1
    assert pool.stats["exhaustions"] == 1
    # a grown request that fails keeps its existing pages
    with pytest.raises(PageExhausted):
        pool.ensure("a", 20)
    assert pool.table("a") == [1, 2]


def test_per_tenant_accounting():
    pool = mk()
    pool.ensure("a", 8, tenant="prod")
    pool.ensure("b", 4, tenant="batch")
    pool.ensure("c", 4, tenant="prod")
    assert pool.tenant_pages("prod") == 3
    assert pool.tenant_pages("batch") == 1
    pool.release("a")
    assert pool.tenant_pages("prod") == 1
    h = pool.health()
    assert h["tenant_pages"] == {"prod": 1, "batch": 1}
    pool.release("b")
    pool.release("c")
    assert pool.health()["tenant_pages"] == {}


def test_prefix_adoption_and_refcounts():
    pool = mk()
    keys = page_content_keys("m", 4, [1, 2, 3, 4, 5, 6, 7, 8], 0)
    assert len(keys) == 2
    pool.ensure("a", 8, tenant="prod")
    pool.publish_keys("a", keys)
    n = pool.adopt_shared("b", keys, tenant="batch")
    assert n == 2
    assert pool.table("b") == pool.table("a")
    # shared pages count once per holder
    assert pool.tenant_pages("batch") == 2
    assert pool.used_pages == 2          # physically still two pages
    # releasing one holder keeps the pages alive for the other
    assert pool.release("a") == 0
    assert pool.used_pages == 2
    assert pool.release("b") == 2
    assert pool.used_pages == 0


def test_adoption_stops_at_first_miss():
    pool = mk()
    keys_a = page_content_keys("m", 4, [1, 2, 3, 4, 9, 9, 9, 9], 0)
    keys_b = page_content_keys("m", 4, [1, 2, 3, 4, 5, 5, 5, 5], 0)
    assert keys_a[0] == keys_b[0]        # same first page
    assert keys_a[1] != keys_b[1]        # diverging second page
    pool.ensure("a", 8)
    pool.publish_keys("a", keys_a)
    assert pool.adopt_shared("b", keys_b) == 1
    pool.ensure("b", 8)                  # second page allocated fresh
    assert pool.table("b")[0] == pool.table("a")[0]
    assert pool.table("b")[1] != pool.table("a")[1]


def test_cow_on_shared_write():
    pool = mk()
    keys = page_content_keys("m", 4, [1, 2, 3, 4, 5, 6], 0)
    pool.ensure("a", 6)
    pool.publish_keys("a", keys)
    pool.adopt_shared("b", keys)
    # position 5 lives in the shared partial page → the writer copies
    page, src = pool.writable_page("b", 5)
    assert src is not None
    assert pool.stats["cow_copies"] == 1
    assert pool.table("b")[1] != pool.table("a")[1]
    # the original keeps its page exclusively now
    page2, src2 = pool.writable_page("a", 5)
    assert src2 is None
    pool.release("a")
    pool.release("b")
    assert pool.used_pages == 0


def test_freed_shared_page_unpublishes_its_key():
    pool = mk()
    keys = page_content_keys("m", 4, [1, 2, 3, 4], 0)
    pool.ensure("a", 4)
    pool.publish_keys("a", keys)
    pool.release("a")
    assert pool.adopt_shared("b", keys) == 0   # key gone with the page


def test_leak_keeps_pages_resident():
    pool = mk()
    pool.ensure("a", 8, tenant="prod")
    assert pool.leak("a") == 2
    assert pool.stats["leaked_pages"] == 2
    assert pool.used_pages == 2          # capacity lost
    assert not pool.holds("a")
    assert pool.tenant_pages("prod") == 0


def test_deterministic_replay_under_seeded_trace():
    """The same request trace replays to the same page map bit-for-bit."""
    rng = np.random.default_rng(42)
    events = []
    live = []
    for i in range(120):
        if live and rng.random() < 0.4:
            events.append(("release", live.pop(int(rng.integers(len(live))))))
        else:
            rid = f"r{i}"
            live.append(rid)
            events.append(("ensure", rid, int(rng.integers(1, 20))))

    def replay():
        pool = mk(num_pages=40, page_size=4)
        snap = []
        for ev in events:
            if ev[0] == "ensure":
                try:
                    snap.append(tuple(pool.ensure(ev[1], ev[2])))
                except PageExhausted:
                    snap.append(("exhausted", ev[1]))
            else:
                snap.append(("freed", ev[1], pool.release(ev[1])))
        h = pool.health()
        snap.append(tuple(sorted(
            (k, tuple(sorted(v.items())) if isinstance(v, dict) else v)
            for k, v in h.items())))
        return snap

    assert replay() == replay()


def test_content_keys_chained_and_meta_aware():
    k1 = page_content_keys("m", 4, [1, 2, 3, 4, 5, 6, 7, 8], 0)
    k2 = page_content_keys("m", 4, [9, 2, 3, 4, 5, 6, 7, 8], 0)
    assert k1[0] != k2[0]
    assert k1[1] != k2[1]                # chaining: later pages diverge too
    # meta tokens shift the stream: same prompt, different keys
    k3 = page_content_keys("m", 4, [1, 2, 3, 4, 5, 6, 7, 8], 2)
    assert k3[0] != k1[0]
    # partial last page gets a fill-tagged key distinct from the full page
    k4 = page_content_keys("m", 4, [1, 2, 3, 4, 5], 0)
    assert len(k4) == 2 and k4[0] == k1[0] and k4[1] != k1[1]
    # model identity is part of the chain seed
    assert page_content_keys("other", 4, [1, 2, 3, 4], 0) != \
        page_content_keys("m", 4, [1, 2, 3, 4], 0)
