"""Resource-aware wave repacking + simulator-guided autotuning.

Property tests (hypothesis when installed, deterministic seeds otherwise)
over the repacker's invariants — on random DAGs AND all four paper
topologies:

  (a) repacked schedules respect every graph dependency;
  (b) no wave's summed ``resource_demand()`` exceeds ``resource_cap``
      (except a single op that alone exceeds it, which runs solo);
  (c) the executed op set — and therefore the union of fusion-group
      members — is preserved exactly.

Plus: the estimate/simulate agreement and speed contract, autotune's
min-makespan guarantee over its candidate space, the api-level autotune
plan cache, and the calibration cache's disk tier.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    SimConfig,
    autotune,
    build_waves,
    estimate_makespan,
    repack_waves,
    schedule,
    simulate,
)
from repro.core import Session, SessionConfig
from repro.core.fusion import fusion_stats
from repro.core.graph import IntensityClass
from repro.core.launch_order import ORDER_POLICIES, validate_order
from repro.core.profiler import ModelProfiler, V5E
from repro.core.stream_alloc import allocate_streams

from conftest import build_inception_like, random_dag

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from benchmarks.workloads import (
    bert_like,
    googlenet_like,
    inception_v3_like,
    t5_like,
)

PAPER_TOPOLOGIES = {
    "googlenet": lambda: googlenet_like(1),
    "inception-v3": lambda: inception_v3_like(1),
    "bert": lambda: bert_like(1, seq=8, n_layers=3),
    "t5": lambda: t5_like(1, seq=8, n_layers=3),
}

TIGHT = SimConfig(resource_cap=24e6, sync_us=0.5, head_of_line=True)


def _check_repack_invariants(g, cfg):
    profiles = ModelProfiler(V5E).profile(g)
    plan = allocate_streams(g)
    order = ORDER_POLICIES["opara"](g, profiles)
    sched = repack_waves(g, plan, order, profiles, cfg=cfg)

    # (c) partition: every op exactly once, fusion groups partition waves
    seen = [op for w in sched.waves for op in w.op_ids]
    assert sorted(seen) == sorted(g.nodes)
    for w in sched.waves:
        grouped = sorted(op for grp in w.fusion_groups for op in grp)
        assert grouped == sorted(w.op_ids)

    # (a) dependencies: producers in strictly earlier waves
    wave_of = {op: w.index for w in sched.waves for op in w.op_ids}
    for node in g:
        for p in node.inputs:
            assert wave_of[p] < wave_of[node.op_id]

    # (b) resource cap per wave (solo oversized ops exempt)
    for w in sched.waves:
        used = sum(profiles[o].cost.resource_demand() for o in w.op_ids)
        assert used <= cfg.resource_cap or len(w.op_ids) == 1

    # flat order is a valid launch order
    validate_order(g, sched.flat_order())
    return sched, profiles


def _check_fusion_members_preserved(g, cfg):
    """Same fusion-group members execute, regrouped but never dropped."""
    profiles = ModelProfiler(V5E).profile(g)
    plan = allocate_streams(g)
    order = ORDER_POLICIES["opara"](g, profiles)
    base = build_waves(g, plan, order)
    packed = repack_waves(g, plan, order, profiles, cfg=cfg)
    members = lambda s: sorted(
        op for w in s.waves for grp in w.fusion_groups for op in grp)
    assert members(base) == members(packed)


if HAVE_HYPOTHESIS:
    dag_strategy = st.builds(
        lambda seed, n, p: random_dag(np.random.default_rng(seed), n, p),
        st.integers(0, 10_000), st.integers(1, 40), st.floats(0.05, 0.9))

    @settings(max_examples=40, deadline=None)
    @given(dag_strategy, st.floats(2e6, 200e6))
    def test_repack_invariants_random_dags(g, cap):
        _check_repack_invariants(
            g, SimConfig(resource_cap=cap, head_of_line=True))

    @settings(max_examples=20, deadline=None)
    @given(dag_strategy)
    def test_repack_preserves_fusion_members_random(g):
        _check_fusion_members_preserved(g, TIGHT)
else:
    @pytest.mark.parametrize("seed", range(20))
    def test_repack_invariants_random_dags(seed):
        g = random_dag(np.random.default_rng(seed), 5 + seed * 2)
        cap = [2e6, 24e6, 200e6][seed % 3]
        _check_repack_invariants(
            g, SimConfig(resource_cap=cap, head_of_line=True))

    @pytest.mark.parametrize("seed", range(8))
    def test_repack_preserves_fusion_members_random(seed):
        g = random_dag(np.random.default_rng(seed), 10 + seed * 3)
        _check_fusion_members_preserved(g, TIGHT)


@pytest.mark.parametrize("name", sorted(PAPER_TOPOLOGIES))
def test_repack_invariants_paper_topologies(name):
    g = PAPER_TOPOLOGIES[name]()
    _check_repack_invariants(g, TIGHT)
    _check_fusion_members_preserved(g, TIGHT)


def test_workload_nodes_own_their_costs():
    """OpCost is mutable (apply_profile writes measured_us in place) —
    workload builders must never share one instance across nodes, or
    hydrated timings cross-contaminate."""
    for name in sorted(PAPER_TOPOLOGIES):
        g = PAPER_TOPOLOGIES[name]()
        ids = [id(n.cost) for n in g]
        assert len(ids) == len(set(ids)), name


def test_repack_mixes_intensity_classes():
    """Complementary fill lowers the same-class overlap fraction vs the
    order-bucketing packer on a class-diverse graph."""
    g = bert_like(1, seq=8, n_layers=3)
    profiles = ModelProfiler(V5E).profile(g)
    classes = {profiles[i].intensity for i in g.nodes}
    assert classes == {IntensityClass.MEMORY, IntensityClass.COMPUTE}, \
        "kind-aware classification must yield both classes at batch 1"
    plan = allocate_streams(g)
    order = ORDER_POLICIES["opara"](g, profiles)
    cfg = SimConfig(resource_cap=128e6, head_of_line=True)
    base = fusion_stats(build_waves(g, plan, order), profiles,
                        cfg.resource_cap)
    packed = fusion_stats(repack_waves(g, plan, order, profiles, cfg=cfg),
                          profiles, cfg.resource_cap)
    assert packed["same_class_overlap_frac"] <= base["same_class_overlap_frac"]


def test_estimate_matches_simulate_under_head_of_line():
    """With non-preemptive dispatch the sweep is a faithful reduction of the
    event-driven simulator."""
    for name in sorted(PAPER_TOPOLOGIES):
        g = PAPER_TOPOLOGIES[name]()
        p = schedule(g, "opara", "opara")
        cfg = SimConfig(resource_cap=52e6, sync_us=0.5, head_of_line=True)
        sim = simulate(g, p.stream_plan, p.order, p.profiles, cfg)
        est = estimate_makespan(g, p.stream_plan, p.order, p.profiles, cfg)
        assert est == pytest.approx(sim.makespan_us, rel=1e-9), name


def test_estimate_tracks_simulate_without_head_of_line():
    """FIFO arbitration differs, but the cost model must still rank
    schedules — keep it within a loose band of the simulator."""
    for seed in range(5):
        g = random_dag(np.random.default_rng(seed), 30)
        p = schedule(g, "opara", "opara")
        cfg = SimConfig(sync_us=0.5)
        sim = simulate(g, p.stream_plan, p.order, p.profiles, cfg)
        est = estimate_makespan(g, p.stream_plan, p.order, p.profiles, cfg)
        assert est == pytest.approx(sim.makespan_us, rel=0.35)


def test_estimate_is_fast():
    """≥10× cheaper than the event-driven simulator on a big graph (the
    acceptance bar is measured on bert-180L in bench_overhead; a 40-layer
    stack keeps the unit test quick while exercising the same asymptotics)."""
    import time
    g = bert_like(1, n_layers=40)
    p = schedule(g, "opara", "opara")
    cfg = SimConfig(resource_cap=128e6, sync_us=0.5, head_of_line=True)
    t0 = time.perf_counter()
    simulate(g, p.stream_plan, p.order, p.profiles, cfg)
    t_sim = time.perf_counter() - t0
    t_est = min(_once(lambda: estimate_makespan(
        g, p.stream_plan, p.order, p.profiles, cfg)) for _ in range(3))
    assert t_sim / t_est >= 10.0


def _once(fn):
    import time
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def test_autotune_never_worse_than_its_candidates():
    cfg = SimConfig(resource_cap=52e6, sync_us=0.5, head_of_line=True)
    for name in sorted(PAPER_TOPOLOGIES):
        g = PAPER_TOPOLOGIES[name]()
        tuned = autotune(g, cfg=cfg)
        assert tuned.n_candidates >= 4
        for alloc in ("opara", "nimble"):
            for order in ("opara", "topo", "critical_path"):
                p = schedule(g, alloc, order)
                est = estimate_makespan(g, p.stream_plan, p.order,
                                        p.profiles, cfg)
                assert tuned.est_makespan_us <= est + 1e-6, (name, alloc, order)


def test_autotune_plan_is_simulatable_and_capturable():
    from repro.core import compile_plan, simulate_plan
    g = build_inception_like(n_blocks=3, width=4)
    cfg = SimConfig(resource_cap=24e6, head_of_line=True)
    tuned = autotune(g, cfg=cfg)
    res = simulate_plan(tuned, cfg)
    assert res.makespan_us > 0
    exe = compile_plan(tuned)         # capture consumes repacked waves
    import jax.numpy as jnp
    outs = exe({"x": jnp.ones((8, 64), jnp.float32)})
    assert outs and all(o.shape == (8, 64) for o in outs)


def test_autotune_stats_surface_repack_efficacy():
    g = bert_like(1, seq=8, n_layers=2)
    tuned = autotune(g, cfg=SimConfig(resource_cap=128e6, head_of_line=True))
    s = tuned.stats()
    for key in ("mean_wave_resource_util", "max_wave_resource_util",
                "same_class_overlap_frac", "repacked", "autotune_ms",
                "n_candidates", "est_makespan_us"):
        assert key in s
    assert s["n_candidates"] >= 4


def test_session_plan_autotune_caches_by_sim_cfg():
    g = build_inception_like(n_blocks=2, width=3, with_payloads=False)
    cfg_a = SimConfig(resource_cap=24e6, head_of_line=True)
    cfg_b = SimConfig(resource_cap=200e6, head_of_line=True)
    sess = Session(autotune=True, sim_cfg=cfg_a)
    p1 = sess.plan(g)
    assert sess.cache_stats()["plan_misses"] == 1
    p2 = sess.plan(g)
    assert p2 is p1
    assert sess.cache_stats()["plan_hits"] == 1
    # same session state, different cost model → distinct tuned plan.  The
    # api shims route per-call config overrides through the same private
    # entry points, so this mirrors the legacy plan(autotune=True, sim_cfg=)
    sess._plan(g, dataclasses.replace(sess.config, sim_cfg=cfg_b))
    assert sess.cache_stats()["plan_misses"] == 2
    sess._plan(g, dataclasses.replace(sess.config, autotune=False))
    assert sess.cache_stats()["plan_misses"] == 3


def test_calibration_survives_memory_clear_via_disk(tmp_path):
    """Process-restart analogue: a second Session (or clear_caches()) drops
    the memory tier, the shared disk tier rehydrates without re-timing."""
    import jax.numpy as jnp
    from conftest import count_measure_calls
    g = build_inception_like(n_blocks=1, width=2)
    inputs = {0: jnp.ones((8, 64), jnp.float32)}
    sess = Session(calib_dir=str(tmp_path / "calib"))
    with count_measure_calls() as calls:
        t1 = sess.calibrate(g, inputs, repeats=1)
        assert calls["n"] == 1
        sess.clear_caches()                 # "restart"
        t2 = sess.calibrate(g, inputs, repeats=1)
        assert calls["n"] == 1, "disk tier must prevent re-timing"
        # a brand-new session pointed at the same disk tier also rehydrates
        sess2 = Session(calib_dir=str(tmp_path / "calib"))
        sess2.calibrate(g, inputs, repeats=1)
        assert calls["n"] == 1
    assert t2.measured_us == t1.measured_us
    stats = sess.cache_stats()   # counters were reset by the "restart"
    assert stats["calib_disk_hits"] == 1 and stats["calib_misses"] == 0


def test_calibration_load_false_skips_disk(tmp_path):
    import jax.numpy as jnp
    from conftest import count_measure_calls
    g = build_inception_like(n_blocks=1, width=2)
    inputs = {0: jnp.ones((8, 64), jnp.float32)}
    sess = Session(calib_dir=str(tmp_path / "calib"))
    with count_measure_calls() as calls:
        sess.calibrate(g, inputs, repeats=1)
        sess.clear_caches()
        # escape hatch: SessionConfig(load_calibration=False) — e.g. after a
        # runtime upgrade invalidates persisted timings
        cold = Session(calib_dir=str(tmp_path / "calib"),
                       load_calibration=False)
        cold.plan(g, measured_inputs=inputs)
        assert calls["n"] == 2, "load_calibration=False must re-measure"
    assert cold.cache_stats()["calib_disk_hits"] == 0


def test_calibration_disk_corruption_falls_back(tmp_path):
    import jax.numpy as jnp
    from repro.core.session import _calib_path, calibration_key
    calib_dir = str(tmp_path / "calib")
    g = build_inception_like(n_blocks=1, width=2)
    inputs = {0: jnp.ones((8, 64), jnp.float32)}
    sess = Session(calib_dir=calib_dir)
    sess.calibrate(g, inputs, repeats=1)
    path = _calib_path(calibration_key(g, inputs, V5E), calib_dir)
    with open(path, "w") as f:
        f.write("{not json")
    sess.clear_caches()
    sess.calibrate(g, inputs, repeats=1)    # must re-measure, not crash
    assert sess.cache_stats()["calib_misses"] == 1
