"""Differential correctness: compiled programs vs naive sequential execution.

Every paper-workload topology from ``benchmarks/workloads.py`` — plus
exporter-built architecture graphs (``models/opgraph_export``) — is made
executable via ``attach_payloads`` (real branch structure, small uniform
payloads) and the full Opara pipeline's output is checked against plain
topo-order op-by-op execution — in analytic and measured modes, cold and
cache-warm.  This is the harness later perf PRs are judged against: any
scheduling/fusion/capture change that alters program SEMANTICS fails here.

Depth-parameterized workloads run shallow variants to keep the suite fast;
the graph builders and payload attachment are identical to the full-size
benchmarks.  Each test drives an explicit :class:`repro.core.Session`, so
cache expectations are local to the test by construction.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Session, detach_profile, run_sequential_uncompiled

from conftest import count_measure_calls

from benchmarks.workloads import (
    arch_workload,
    attach_payloads,
    bert_like,
    googlenet_like,
    inception_v3_like,
    t5_like,
)

D, TOKENS = 32, 4

# Shallow-where-possible variants of every PAPER_WORKLOADS entry, plus
# exporter-built arch graphs: one dense LM (QKV / gate∥up branches) and one
# MoE LM (expert fan-out + dispatch/combine scatter nodes) so the compiled
# executor is differentially checked on graphs the exporter actually emits,
# not only the hand-built paper topologies.
WORKLOADS = {
    "googlenet": lambda: googlenet_like(1),
    "inception-v3": lambda: inception_v3_like(1),
    "bert": lambda: bert_like(1, seq=4, n_layers=2),
    "t5": lambda: t5_like(1, seq=4, n_layers=2),
    "arch-qwen2": lambda: arch_workload("qwen2-0.5b", seq=4, n_layers=2),
    "arch-kimi-moe": lambda: arch_workload("kimi-k2-1t-a32b", seq=4,
                                           n_layers=2),
}


@pytest.fixture
def sess():
    return Session()


def _build(name, seed=0):
    g = attach_payloads(WORKLOADS[name](), d=D, tokens=TOKENS, seed=seed)
    input_nodes = [n for n in g if n.fn is None]
    x = jnp.asarray(
        np.random.default_rng(99).standard_normal((TOKENS, D)), jnp.float32)
    by_name = {n.name: x for n in input_nodes}
    by_id = {n.op_id: x for n in input_nodes}
    return g, by_name, by_id


def _assert_matches(got, ref):
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_differential_analytic_cold_and_warm(name, sess):
    g, inputs, _ = _build(name)
    exe_cold = sess.optimize(g)
    # the oracle reads the SAME outputs the compiled program returns
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe_cold.output_ids)
    _assert_matches(exe_cold(inputs), ref)
    exe_warm = sess.optimize(g)
    assert exe_warm is exe_cold, "warm optimize must hit the executable cache"
    _assert_matches(exe_warm(inputs), ref)
    stats = sess.cache_stats()
    assert stats["plan_hits"] >= 1 and stats["exec_hits"] == 1


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_differential_measured_cold_and_warm(name, sess):
    g, inputs, minputs = _build(name)
    ref = run_sequential_uncompiled(g, inputs)

    # cold: one profiling inference hydrates the graph, then schedule+capture
    sess.calibrate(g, minputs, repeats=1)
    sess.plan(g, measured_inputs=minputs)
    assert g.calibration_fp is not None
    exe_cold = sess.optimize(g)
    _assert_matches(exe_cold(inputs), ref)

    # warm: same-signature re-schedule does zero re-timing
    with count_measure_calls() as timing:
        sess.plan(g, measured_inputs=minputs)
        exe_warm = sess.optimize(g)
    assert timing["n"] == 0, "warm measured schedule must not re-time"
    assert exe_warm is exe_cold
    _assert_matches(exe_warm(inputs), ref)
    stats = sess.cache_stats()
    assert stats["calib_hits"] >= 2 and stats["calib_misses"] == 1

    # detaching the profile returns the graph to its analytic identity
    table = detach_profile(g)
    assert table is not None and g.calibration_fp is None
    exe_analytic = sess.optimize(g)
    assert exe_analytic is not exe_cold
    _assert_matches(exe_analytic(inputs), ref)


def test_calibration_survives_checkpoint_reload(sess):
    """A structurally identical rebuilt graph (the reloaded-checkpoint
    scenario) hydrates from the calibration cache: zero re-timing, warm
    plan-cache path."""
    g1, _, minputs = _build("bert")
    with count_measure_calls() as timing:
        p1 = sess.plan(g1, measured_inputs=minputs)
        assert timing["n"] == 1

        g2, inputs2, minputs2 = _build("bert")  # fresh object, same structure
        assert g2 is not g1
        p2 = sess.plan(g2, measured_inputs=minputs2)
    assert timing["n"] == 1, "reloaded graph must reuse the measured profile"
    stats = sess.cache_stats()
    assert stats["calib_hits"] == 1 and stats["calib_misses"] == 1
    assert stats["plan_hits"] == 1 and stats["plan_misses"] == 1
    assert p2.graph is g2 and p2.order == p1.order
    # hydrated timings are byte-identical to the measured originals
    assert g2.calibration_fp == g1.calibration_fp
    ref = run_sequential_uncompiled(g2, inputs2)
    _assert_matches(sess.optimize(g2)(inputs2), ref)


def test_measured_and_analytic_plans_do_not_collide(sess):
    """Same structure, one calibrated and one not → distinct plan entries."""
    from repro.core import graph_signature
    g1, _, minputs = _build("bert")
    g2, _, _ = _build("bert")
    sess.plan(g1, measured_inputs=minputs)
    sess.plan(g2)  # analytic
    stats = sess.cache_stats()
    assert stats["plan_misses"] == 2 and stats["plan_hits"] == 0
    assert graph_signature(g1) != graph_signature(g2)


# -- routed MoE: REAL ragged dispatch/combine payloads ------------------------

def _build_arch(arch: str, n_layers: int, seed: int = 0,
                dtype=jnp.float32, cap_scale: float = 1.0, seq: int = 4):
    """Exporter-built arch graph with real payloads threaded end to end
    (decomposed attention stages, ssm scans, ragged MoE fan-out where the
    config has one).  fp32 weights by default so stacked-vs-sequential
    execution must agree to fp32 tolerance; pass bf16 to exercise the
    low-precision stacking path.  ``cap_scale`` < 1 shrinks the MoE
    capacities to force genuine overflow re-routing."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import make_model
    from repro.models.opgraph_export import build_lm_opgraph

    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype=dtype)
    model = make_model(cfg)
    params = model.init(jax.random.key(seed))
    g = build_lm_opgraph(cfg, batch=1, seq=seq, params=params,
                         n_layers=n_layers, moe_cap_scale=cap_scale)
    tokens = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab_size, (1, seq)),
        jnp.int32)
    input_ids = [n.op_id for n in g if n.fn is None]
    assert len(input_ids) == 1, "arch export must be fully payload-backed"
    return g, {"tokens": tokens}, {input_ids[0]: tokens}


def _build_routed_moe(arch: str, n_layers: int, seed: int = 0):
    return _build_arch(arch, n_layers, seed)


# kimi-k2 smoke: 1 dense-prefix + MoE layers; deepseek-v3 smoke: 3 dense
# (MLA attention) + 1 MoE layer — both reach real routed expert fan-out.
MOE_ARCHS = {"kimi-k2-1t-a32b": 3, "deepseek-v3-671b": 4}


@pytest.mark.parametrize("arch", sorted(MOE_ARCHS))
def test_differential_routed_moe_analytic_cold_and_warm(arch, sess):
    g, inputs, _ = _build_routed_moe(arch, MOE_ARCHS[arch])
    # the export is genuinely ragged: per-expert capacities differ
    caps = {n.out_shape[0] for n in g if ".dispatch" in n.name}
    assert len(caps) > 1, f"expert capacities not ragged: {caps}"
    exe_cold = sess.optimize(g)
    assert exe_cold.program_stats()["n_grouped_gemm"] >= 1, (
        "routed fan-out must exercise the grouped ragged-M kernel")
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe_cold.output_ids)
    _assert_matches(exe_cold(inputs), ref)
    exe_warm = sess.optimize(g)
    assert exe_warm is exe_cold
    _assert_matches(exe_warm(inputs), ref)
    assert sess.cache_stats()["exec_hits"] == 1


@pytest.mark.parametrize("arch", sorted(MOE_ARCHS))
def test_differential_routed_moe_measured_cold_and_warm(arch, sess):
    g, inputs, minputs = _build_routed_moe(arch, MOE_ARCHS[arch])
    sess.calibrate(g, minputs, repeats=1)
    sess.plan(g, measured_inputs=minputs)
    assert g.calibration_fp is not None
    exe_cold = sess.optimize(g)
    assert exe_cold.program_stats()["n_grouped_gemm"] >= 1
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe_cold.output_ids)
    _assert_matches(exe_cold(inputs), ref)

    with count_measure_calls() as timing:
        sess.plan(g, measured_inputs=minputs)
        exe_warm = sess.optimize(g)
    assert timing["n"] == 0, "warm measured schedule must not re-time"
    assert exe_warm is exe_cold
    _assert_matches(exe_warm(inputs), ref)


def test_routed_moe_expert_counts_unequal():
    """The routed fan-out sees genuinely unequal per-expert token counts at
    run time (not just unequal capacities): recompute the export's routing
    decision and check the expert histogram is non-uniform."""
    import jax

    from repro.configs import get_config
    from repro.models.opgraph_export import _topk_routing

    g, inputs, _ = _build_routed_moe("kimi-k2-1t-a32b", 3)
    router = next(n for n in g if n.name.endswith("L1.router"))
    nb = router.out_shape[-1]
    moe = get_config("kimi-k2-1t-a32b", smoke=True).moe
    top_k, aux_free = min(moe.top_k, nb), moe.router_aux_free
    # replay the graph up to the router and read its logits
    vals = {}
    for node in g:
        if node.fn is None:
            vals[node.op_id] = inputs[node.name]
        else:
            vals[node.op_id] = node.fn(
                *[vals[p] for p in node.inputs],
                *node.meta.get("consts", ()))
        if node.op_id == router.op_id:
            break
    _, top_idx = _topk_routing(vals[router.op_id], nb, top_k=top_k,
                               aux_free=aux_free)
    counts = np.bincount(np.asarray(top_idx).reshape(-1), minlength=nb)
    assert counts.sum() == 4 * top_k         # 4 tokens × top-k
    assert len(set(counts.tolist())) > 1, counts


def test_attach_payloads_strips_branch_gemm_markers():
    """Exporter graphs carry payload="matmul" markers on GEMM nodes; the
    generic differential payload is not a matmul, so attachment must remove
    the marker — otherwise capture would route stacked groups to the fused
    GEMM kernel and compute the wrong function."""
    import jax
    from repro.configs import get_config
    from repro.models import make_model
    from repro.models.opgraph_export import build_lm_opgraph

    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    g = build_lm_opgraph(cfg, batch=1, seq=4, params=params, n_layers=2)
    assert any(n.meta.get("payload") == "matmul" for n in g)
    attach_payloads(g, d=D, tokens=TOKENS)
    assert not any("payload" in n.meta for n in g)


# -- newly decomposed archs: traced-kernel graphs end to end ------------------
#
# ISSUE 10: every arch family must pass the differential harness at the new
# granularity — decomposed attention stages (glm4 exercises the (w, b) bias
# consts path), parallel attn∥mamba with real scan payloads (hymba), and
# the RWKV6 token-shift/decay/WKV-scan chain.

DECOMPOSED_ARCHS = {"glm4-9b": 2, "hymba-1.5b": 2, "rwkv6-1.6b": 2}


@pytest.mark.parametrize("arch", sorted(DECOMPOSED_ARCHS))
def test_differential_decomposed_arch(arch, sess):
    g, inputs, _ = _build_arch(arch, DECOMPOSED_ARCHS[arch])
    # granularity reached the executable export, not only the cost model
    stage = ".wkv_scan" if arch.startswith("rwkv") else ".softmax"
    assert any(n.name.endswith(stage) for n in g)
    exe = sess.optimize(g)
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe.output_ids)
    _assert_matches(exe(inputs), ref)
    exe_warm = sess.optimize(g)
    assert exe_warm is exe
    _assert_matches(exe_warm(inputs), ref)


def test_differential_whisper_encdec(sess):
    """Encoder-decoder export with real payloads: two INPUT nodes (frames +
    tokens), cross-attention K/V branching off the encoder output."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import make_model
    from repro.models.opgraph_export import build_encdec_opgraph

    cfg = dataclasses.replace(get_config("whisper-medium", smoke=True),
                              dtype=jnp.float32)
    params = make_model(cfg).init(jax.random.key(0))
    g = build_encdec_opgraph(cfg, 1, 4, n_layers=2, params=params)
    assert any(n.name.endswith(".cross_softmax") for n in g)
    rng = np.random.default_rng(7)
    inputs = {
        "frames": jnp.asarray(
            rng.standard_normal(
                (1, cfg.frontend.n_tokens, cfg.frontend.feat_dim)),
            jnp.float32),
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 4)),
                              jnp.int32),
    }
    exe = sess.optimize(g)
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe.output_ids)
    _assert_matches(exe(inputs), ref)


@pytest.mark.parametrize("arch,n_layers", [("qwen2-0.5b", 2),
                                           ("kimi-k2-1t-a32b", 3)])
def test_differential_bf16_weights(arch, n_layers, sess):
    """bf16-weight exports: the capture pipeline (stacked vmap payloads,
    fused branch GEMMs, grouped ragged-M kernels) must agree with op-by-op
    sequential replay in low precision too.  Tolerance is bf16-scale: both
    sides run the same math, but fusion may reassociate reductions."""
    g, inputs, _ = _build_arch(arch, n_layers, dtype=jnp.bfloat16)
    assert any(n.out_dtype == jnp.bfloat16 or
               any(jnp.asarray(c).dtype == jnp.bfloat16
                   for c in n.meta.get("consts", ()))
               for n in g if n.fn is not None)
    exe = sess.optimize(g)
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe.output_ids)
    got = exe(inputs)
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(
            np.asarray(a, jnp.float32), np.asarray(b, jnp.float32),
            rtol=2e-2, atol=2e-2)


def test_moe_capacity_overflow_matches_sort_dispatch(sess):
    """Production dispatch semantics under overflow: with capacities scaled
    below the routed load, pairs whose within-expert rank exceeds capacity
    are DROPPED (contribute zero), exactly like the stable-sort dispatch in
    ``repro.models.ffn.moe_ffn_sort`` — the exporter's cumsum rank equals
    the within-expert rank of a stable sort by expert id.  Verifies (a) the
    compiled pipeline still matches sequential replay, (b) overflow really
    happens, (c) every dispatch buffer equals the sort-based reference."""
    from repro.configs import get_config
    from repro.models.opgraph_export import _topk_routing

    g, inputs, _ = _build_arch("kimi-k2-1t-a32b", 3, cap_scale=0.25, seq=8)
    exe = sess.optimize(g)
    ref = run_sequential_uncompiled(g, inputs, output_ids=exe.output_ids)
    _assert_matches(exe(inputs), ref)

    # replay op-by-op and check one MoE layer's dispatch rows
    vals = {}
    for node in g:
        vals[node.op_id] = (inputs[node.name] if node.fn is None else
                            node.fn(*[vals[p] for p in node.inputs],
                                    *node.meta.get("consts", ())))
    router = next(n for n in g if n.name == "L1.router")
    n2 = next(n for n in g if n.name == "L1.norm2")
    disps = sorted((n for n in g if n.name.startswith("L1.dispatch")),
                   key=lambda n: int(n.name.rsplit("dispatch", 1)[1]))
    nb = router.out_shape[-1]
    moe = get_config("kimi-k2-1t-a32b", smoke=True).moe
    top_k = min(moe.top_k, nb)
    _, top_idx = _topk_routing(vals[router.op_id], nb, top_k,
                               moe.router_aux_free)
    expert_flat = np.asarray(top_idx).reshape(-1)
    tok = np.repeat(np.arange(expert_flat.size // top_k), top_k)
    caps = [n.out_shape[0] for n in disps]
    counts = np.bincount(expert_flat, minlength=nb)
    assert any(counts[j] > caps[j] for j in range(nb)), (
        f"capacities {caps} never overflow (counts {counts}) — the "
        f"re-routing path is untested")
    d = vals[n2.op_id].shape[-1]
    xf = np.asarray(vals[n2.op_id]).reshape(-1, d)
    for j, n in enumerate(disps):
        pairs = np.where(expert_flat == j)[0][: caps[j]]   # stable order
        want = np.zeros((caps[j], xf.shape[-1]), xf.dtype)
        want[: len(pairs)] = xf[tok[pairs]]
        np.testing.assert_allclose(np.asarray(vals[n.op_id]), want,
                                   rtol=1e-6, atol=1e-6)
