"""Differential correctness: compiled programs vs naive sequential execution.

Every paper-workload topology from ``benchmarks/workloads.py`` is made
executable via ``attach_payloads`` (real branch structure, small uniform
payloads) and the full Opara pipeline's output is checked against plain
topo-order op-by-op execution — in analytic and measured modes, cold and
cache-warm.  This is the harness later perf PRs are judged against: any
scheduling/fusion/capture change that alters program SEMANTICS fails here.

Depth-parameterized workloads run shallow variants to keep the suite fast;
the graph builders and payload attachment are identical to the full-size
benchmarks.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api as opara, run_sequential_uncompiled
from repro.core import detach_profile

from conftest import count_measure_calls

from benchmarks.workloads import (
    attach_payloads,
    bert_like,
    googlenet_like,
    inception_v3_like,
    t5_like,
)

D, TOKENS = 32, 4

# Shallow-where-possible variants of every PAPER_WORKLOADS entry.
WORKLOADS = {
    "googlenet": lambda: googlenet_like(1),
    "inception-v3": lambda: inception_v3_like(1),
    "bert": lambda: bert_like(1, seq=4, n_layers=2),
    "t5": lambda: t5_like(1, seq=4, n_layers=2),
}


@pytest.fixture(autouse=True)
def _fresh_caches():
    opara.clear_caches()
    yield
    opara.clear_caches()


def _build(name, seed=0):
    g = attach_payloads(WORKLOADS[name](), d=D, tokens=TOKENS, seed=seed)
    input_nodes = [n for n in g if n.fn is None]
    x = jnp.asarray(
        np.random.default_rng(99).standard_normal((TOKENS, D)), jnp.float32)
    by_name = {n.name: x for n in input_nodes}
    by_id = {n.op_id: x for n in input_nodes}
    return g, by_name, by_id


def _assert_matches(got, ref):
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_differential_analytic_cold_and_warm(name):
    g, inputs, _ = _build(name)
    ref = run_sequential_uncompiled(g, inputs)
    exe_cold = opara.optimize(g)
    _assert_matches(exe_cold(inputs), ref)
    exe_warm = opara.optimize(g)
    assert exe_warm is exe_cold, "warm optimize must hit the executable cache"
    _assert_matches(exe_warm(inputs), ref)
    stats = opara.cache_stats()
    assert stats["plan_hits"] >= 1 and stats["exec_hits"] == 1


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_differential_measured_cold_and_warm(name):
    g, inputs, minputs = _build(name)
    ref = run_sequential_uncompiled(g, inputs)

    # cold: one profiling inference hydrates the graph, then schedule+capture
    opara.calibrate(g, minputs, repeats=1)
    opara.plan(g, measured_inputs=minputs)
    assert g.calibration_fp is not None
    exe_cold = opara.optimize(g)
    _assert_matches(exe_cold(inputs), ref)

    # warm: same-signature re-schedule does zero re-timing
    with count_measure_calls() as timing:
        opara.plan(g, measured_inputs=minputs)
        exe_warm = opara.optimize(g)
    assert timing["n"] == 0, "warm measured schedule must not re-time"
    assert exe_warm is exe_cold
    _assert_matches(exe_warm(inputs), ref)
    stats = opara.cache_stats()
    assert stats["calib_hits"] >= 2 and stats["calib_misses"] == 1

    # detaching the profile returns the graph to its analytic identity
    table = detach_profile(g)
    assert table is not None and g.calibration_fp is None
    exe_analytic = opara.optimize(g)
    assert exe_analytic is not exe_cold
    _assert_matches(exe_analytic(inputs), ref)


def test_calibration_survives_checkpoint_reload():
    """A structurally identical rebuilt graph (the reloaded-checkpoint
    scenario) hydrates from the calibration cache: zero re-timing, warm
    plan-cache path — the acceptance criterion for this PR."""
    g1, _, minputs = _build("bert")
    with count_measure_calls() as timing:
        p1 = opara.plan(g1, measured_inputs=minputs)
        assert timing["n"] == 1

        g2, inputs2, minputs2 = _build("bert")  # fresh object, same structure
        assert g2 is not g1
        p2 = opara.plan(g2, measured_inputs=minputs2)
    assert timing["n"] == 1, "reloaded graph must reuse the measured profile"
    stats = opara.cache_stats()
    assert stats["calib_hits"] == 1 and stats["calib_misses"] == 1
    assert stats["plan_hits"] == 1 and stats["plan_misses"] == 1
    assert p2.graph is g2 and p2.order == p1.order
    # hydrated timings are byte-identical to the measured originals
    assert g2.calibration_fp == g1.calibration_fp
    ref = run_sequential_uncompiled(g2, inputs2)
    _assert_matches(opara.optimize(g2)(inputs2), ref)


def test_measured_and_analytic_plans_do_not_collide():
    """Same structure, one calibrated and one not → distinct plan entries."""
    g1, _, minputs = _build("bert")
    g2, _, _ = _build("bert")
    opara.plan(g1, measured_inputs=minputs)
    opara.plan(g2)  # analytic
    stats = opara.cache_stats()
    assert stats["plan_misses"] == 2 and stats["plan_hits"] == 0
    assert opara.graph_signature(g1) != opara.graph_signature(g2)
