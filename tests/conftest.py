"""Shared fixtures + graph builders for the test suite.

NOTE: no XLA_FLAGS here — smoke tests and benches must see ONE device;
only the dry-run (its own process) forces 512 host devices.
"""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import OpGraph, OpKind
from repro.core.profiler import ModelProfiler, elementwise_cost, gemm_cost, norm_cost


@pytest.fixture(autouse=True)
def _fresh_default_session(tmp_path, monkeypatch):
    """Full cross-test isolation of the process-global compilation state.

    * ``$REPRO_CALIB_DIR`` points at a per-test directory — tests
      model-check the in-memory LRU counters, and a populated
      ``~/.cache/repro/calib`` from an earlier run (or test) would turn
      expected misses into disk hits;
    * the default :class:`repro.core.Session` (which backs the legacy
      ``repro.core.api`` shims) is replaced with a fresh one — empty
      plan/exec/calib caches, zeroed counters — before AND after each test,
      so no test needs ad-hoc ``clear_caches()`` bracketing and no test can
      leak warm cache entries into the next;
    * ``$REPRO_FAULT_PLAN`` is cleared — a chaos run (scripts/chaos_smoke.py)
      arms it per-invocation, but the regular suite must always see the
      fault-free path unless a test arms a plan explicitly."""
    from repro.core.session import reset_default_session
    from repro.runtime.guard import reset_kernel_log

    monkeypatch.setenv("REPRO_CALIB_DIR", str(tmp_path / "calib"))
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    reset_default_session()
    reset_kernel_log()
    yield
    reset_default_session()
    reset_kernel_log()


@contextlib.contextmanager
def count_measure_calls():
    """Patch ModelProfiler.measure with a call counter (restored on exit).
    Yields a dict whose ``n`` tracks how many profiling inferences ran —
    the zero-re-timing assertions of the calibration-cache tests."""
    calls = {"n": 0}
    orig = ModelProfiler.measure

    def counting(self, graph, inputs, repeats=3):
        calls["n"] += 1
        return orig(self, graph, inputs, repeats=repeats)

    ModelProfiler.measure = counting
    try:
        yield calls
    finally:
        ModelProfiler.measure = orig


def build_inception_like(n_blocks: int = 3, width: int = 4, d: int = 64,
                         tokens: int = 8, with_payloads: bool = True,
                         seed: int = 0):
    """Branchy DAG shaped like the paper's GoogLeNet/Inception motivation."""
    rng = np.random.default_rng(seed)
    g = OpGraph("incep")
    inp = g.add("x", OpKind.INPUT, out_shape=(tokens, d))
    cur = inp
    weights = []
    for blk in range(n_blocks):
        outs = []
        for b in range(width):
            w = jnp.asarray(rng.standard_normal((d, d)) * 0.05, jnp.float32)
            weights.append(w)
            # per-branch weight declared via meta["consts"] so the capturer
            # can stack branches into one fused kernel (capture contract)
            fn = (lambda x, w: x @ w) if with_payloads else None
            c = g.add(f"b{blk}_{b}_gemm", OpKind.GEMM, [cur], fn=fn,
                      cost=gemm_cost(tokens, d, d, 4),
                      fuse_sig=("gemm", tokens, d, d),
                      consts=(w,) if with_payloads else (),
                      **({"payload": "matmul"} if with_payloads else {}))
            fn2 = jax.nn.relu if with_payloads else None
            r = g.add(f"b{blk}_{b}_relu", OpKind.ELEMENTWISE, [c], fn=fn2,
                      cost=elementwise_cost(tokens * d, 4),
                      fuse_sig=("relu", tokens, d))
            outs.append(r)
        fn3 = (lambda *xs: sum(xs)) if with_payloads else None
        cur = g.add(f"b{blk}_sum", OpKind.ELEMENTWISE, outs, fn=fn3,
                    cost=elementwise_cost(tokens * d, 4, n_in=width))
    g.validate()
    return g


def random_dag(rng: np.random.Generator, n: int, p_edge: float = 0.3,
               p_heavy: float = 0.3):
    """Random DAG with mixed compute/memory op costs (no payloads)."""
    g = OpGraph("rand")
    ids = []
    for i in range(n):
        preds = [j for j in ids if rng.random() < p_edge][-4:]
        if i == 0:
            preds = []
        kind = OpKind.GEMM if rng.random() < p_heavy else OpKind.ELEMENTWISE
        if kind is OpKind.GEMM:
            m = int(rng.integers(8, 128))
            cost = gemm_cost(m, 256, 256, 4)
        else:
            cost = elementwise_cost(int(rng.integers(1, 64)) * 1024, 4)
        ids.append(g.add(f"op{i}", kind, preds, cost=cost))
    g.validate()
    return g


@pytest.fixture
def inception_graph():
    return build_inception_like()
