"""Data pipeline: determinism, host sharding, elastic repartition."""
import numpy as np

from repro.data import make_dataset


def test_deterministic_given_seed():
    d1 = make_dataset(1000, 32, 8, seed=7)
    d2 = make_dataset(1000, 32, 8, seed=7)
    b1, b2 = d1.batch_at(5), d2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    d = make_dataset(1000, 32, 4)
    b = d.batch_at(0)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_hosts_read_disjoint_shards():
    full = make_dataset(1000, 16, 8, n_hosts=1, host_id=0).batch_at(3)
    h0 = make_dataset(1000, 16, 8, n_hosts=2, host_id=0).batch_at(3)
    h1 = make_dataset(1000, 16, 8, n_hosts=2, host_id=1).batch_at(3)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])


def test_repartition_preserves_stream():
    d = make_dataset(1000, 16, 8, n_hosts=2, host_id=0)
    for _ in range(4):
        next(iter(d))
    d2 = d.repartition(n_hosts=4, host_id=1)
    assert d2.step == d.step
    # global content at a step is identical regardless of partitioning
    full = make_dataset(1000, 16, 8).batch_at(d.step)["tokens"]
    part = d2.batch_at(d2.step)["tokens"]
    np.testing.assert_array_equal(part, full[2:4])


def test_state_dict_roundtrip():
    d = make_dataset(1000, 16, 4)
    it = iter(d)
    next(it); next(it); next(it)
    state = d.state_dict()
    d2 = make_dataset(1000, 16, 4)
    d2.load_state_dict(state)
    np.testing.assert_array_equal(d.batch_at(d.step)["tokens"],
                                  d2.batch_at(d2.step)["tokens"])
