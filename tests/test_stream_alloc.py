"""Unit tests for Algorithm 1 (Stream Allocator) and the Nimble baseline."""
import numpy as np
import pytest

from repro.core.graph import OpGraph, OpKind, sequential_chain
from repro.core.nimble import allocate_streams_nimble
from repro.core.stream_alloc import allocate_streams, count_syncs, validate_plan

from conftest import build_inception_like


def test_chain_single_stream():
    g = sequential_chain(10)
    plan = allocate_streams(g)
    validate_plan(g, plan)
    assert plan.n_streams == 1
    assert count_syncs(g, plan) == 0


def test_parallel_branches_get_parallel_streams():
    g = OpGraph()
    root = g.add("root", OpKind.INPUT)
    branches = [g.add(f"b{i}", OpKind.GEMM, [root]) for i in range(5)]
    g.add("join", OpKind.ELEMENTWISE, branches)
    plan = allocate_streams(g)
    validate_plan(g, plan)
    # 5 independent branches must land on 5 distinct streams
    assert len({plan.stream_of[b] for b in branches}) == 5


def test_first_successor_inherits_stream():
    g = OpGraph()
    a = g.add("a", OpKind.GEMM)
    b = g.add("b", OpKind.GEMM, [a])   # first successor of a
    c = g.add("c", OpKind.GEMM, [a])   # second successor → new stream
    plan = allocate_streams(g)
    assert plan.stream_of[b] == plan.stream_of[a]
    assert plan.stream_of[c] != plan.stream_of[a]


def test_inception_stream_count_exceeds_nimble(inception_graph):
    """Paper §5.2: Opara launches MORE streams than Nimble (28 vs 4 for
    GoogLeNet) — lanes are not limited to a minimum path cover."""
    opara = allocate_streams(inception_graph)
    nimble = allocate_streams_nimble(inception_graph)
    validate_plan(inception_graph, opara)
    validate_plan(inception_graph, nimble)
    assert opara.n_streams >= nimble.n_streams


def test_nimble_diamond_is_min_path_cover():
    # a → (b, c) → d : minimum path cover = 2 chains
    g = OpGraph()
    a = g.add("a", OpKind.GEMM)
    b = g.add("b", OpKind.GEMM, [a])
    c = g.add("c", OpKind.GEMM, [a])
    g.add("d", OpKind.GEMM, [b, c])
    plan = allocate_streams_nimble(g)
    assert plan.n_streams == 2


def test_syncs_only_on_cross_stream_edges(inception_graph):
    plan = allocate_streams(inception_graph)
    syncs = count_syncs(inception_graph, plan)
    cross = sum(
        1
        for node in inception_graph
        for p in set(node.inputs)
        if plan.stream_of[p] != plan.stream_of[node.op_id]
    )
    assert syncs == cross
