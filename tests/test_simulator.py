"""Simulator semantics: bounds, interference, launch-order effects (the
paper's Fig. 2 / Fig. 3 phenomena reproduced as assertions)."""
import numpy as np
import pytest

from repro.core import (
    SimConfig,
    schedule,
    sequential_makespan,
    simulate_plan,
)
from repro.core.graph import OpCost, OpGraph, OpKind
from repro.core.profiler import ModelProfiler, V5E

from conftest import build_inception_like, random_dag


def _mk(flops=0.0, byts=0.0, vmem=1e6):
    return OpCost(flops=flops, bytes_read=byts, bytes_written=byts / 4,
                  vmem_bytes=vmem)


def test_makespan_bounded_by_critical_path_and_sequential():
    rng = np.random.default_rng(0)
    for seed in range(5):
        g = random_dag(np.random.default_rng(seed), 30)
        plan = schedule(g, "opara", "opara")
        cfg = SimConfig(sync_us=0.0, interference_penalty=0.0)
        res = simulate_plan(plan, cfg)
        seq = sequential_makespan(g, plan.profiles, cfg)
        durations = {i: plan.profiles[i].est_us for i in g.nodes}
        cp = g.critical_path_cost(durations)
        assert res.makespan_us <= seq + 1e-6
        assert res.makespan_us >= cp - 1e-6


def test_parallel_beats_sequential_on_branchy_graph():
    g = build_inception_like(n_blocks=4, width=6, d=512, tokens=256,
                             with_payloads=False)
    cfg = SimConfig(sync_us=0.05, interference_penalty=0.13)
    opara = simulate_plan(schedule(g, "opara", "opara"), cfg)
    seq = sequential_makespan(g, schedule(g, "sequential", "topo").profiles, cfg)
    assert opara.makespan_us < seq


def test_interference_alternation_beats_same_class_bursts():
    """Fig. 3: overlapping compute with memory ops beats same-class overlap."""
    g = OpGraph()
    root = g.add("root", OpKind.INPUT)
    for i in range(4):
        g.add(f"c{i}", OpKind.GEMM, [root], cost=_mk(flops=5e9, byts=1e6))
        g.add(f"m{i}", OpKind.ELEMENTWISE, [root], cost=_mk(flops=1e3, byts=2e7))
    cfg = SimConfig(sync_us=0.0, interference_penalty=0.3)
    res_opara = simulate_plan(schedule(g, "opara", "opara"), cfg)
    res_topo = simulate_plan(schedule(g, "opara", "topo"), cfg)
    assert res_opara.makespan_us <= res_topo.makespan_us * 1.001


def test_graph_capture_removes_launch_overhead():
    """PyTorch-eager vs CUDA-Graph gap (paper Fig. 5a: 1.85–4.18×)."""
    g = build_inception_like(n_blocks=4, width=4, with_payloads=False)
    plan = schedule(g, "sequential", "topo")
    with_graph = sequential_makespan(g, plan.profiles, SimConfig(graph_capture=True))
    without = sequential_makespan(g, plan.profiles, SimConfig(graph_capture=False))
    assert without > with_graph * 1.5


def test_resource_cap_blocks_concurrency():
    g = OpGraph()
    root = g.add("root", OpKind.INPUT)
    for i in range(4):
        g.add(f"fat{i}", OpKind.GEMM, [root],
              cost=_mk(flops=1e9, byts=1e6, vmem=100e6))
    plan = schedule(g, "opara", "opara")
    tight = simulate_plan(plan, SimConfig(resource_cap=128e6, sync_us=0.0))
    loose = simulate_plan(plan, SimConfig(resource_cap=1e12, sync_us=0.0))
    assert tight.makespan_us >= loose.makespan_us
