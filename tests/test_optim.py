"""Optimizer, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    adamw_init,
    adamw_update,
    compress_grads,
    cosine_schedule,
    init_compression,
    wsd_schedule,
)


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    for _ in range(300):
        g = jax.grad(lambda p: ((p["w"] - target) ** 2).sum())(params)
        params, state, _ = adamw_update(g, state, params, lr=0.05,
                                        weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e9)}
    new_params, state, metrics = adamw_update(huge, state, params, lr=0.1,
                                              clip_norm=1.0, weight_decay=0.0)
    assert float(metrics["grad_norm"]) > 1e8
    assert float(jnp.abs(new_params["w"]).max()) < 1.0


def test_schedules_shape():
    lrs = [float(cosine_schedule(s, 1e-3, warmup=10, total=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9           # warmup ascends
    assert lrs[-1] < lrs[20]                        # cosine descends
    w = [float(wsd_schedule(s, 1e-3, 10, 50, 20)) for s in range(90)]
    assert abs(w[30] - 1e-3) < 1e-9                 # stable plateau
    assert w[-1] < w[30]                            # decay tail


def test_int8_compression_error_feedback():
    """Error feedback: sum of transmitted grads converges to the true sum."""
    params = {"w": jnp.zeros(64)}
    state = init_compression(params, "int8")
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64)
    sent_sum = np.zeros(64)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64) * 0.1, jnp.float32)}
        true_sum += np.asarray(g["w"])
        sent, state = compress_grads(g, state, "int8")
        sent_sum += np.asarray(sent["w"], np.float32)
    resid = np.abs(true_sum - sent_sum).max()
    assert resid < 0.05, f"error feedback residual too large: {resid}"


def test_topk_compression_sparsity():
    params = {"w": jnp.zeros(1000)}
    state = init_compression(params, "topk")
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                          jnp.float32)}
    sent, state = compress_grads(g, state, "topk")
    nnz = int((np.asarray(sent["w"]) != 0).sum())
    assert nnz <= 20  # k_frac=0.01 of 1000 + ties
