"""Overload-robust admission tier: bounded queue, quotas, EDF assembly,
shedding, deadline expiry, preemption, drain/health lifecycle, watchdog
probation, and multi-slot determinism under compiled AND eager decode."""
import jax
import pytest

from repro.configs import get_config
from repro.models import make_model
from repro.runtime.faults import FaultPlan
from repro.serving import (AdmissionConfig, AdmissionQueue, InferenceEngine,
                           Request, RequestState, TERMINAL_STATES)
from repro.serving.admission import deadline_critical, feasible


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("llama3.2-1b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _req(rid, priority=0, deadline=None, ttl=None, tenant="default",
         max_tokens=4, prompt=None):
    return Request(rid=rid, prompt=prompt or [1 + rid, 2, 3],
                   max_tokens=max_tokens, tenant=tenant, priority=priority,
                   deadline=deadline, ttl=ttl)


# =========================================================================
# AdmissionQueue (pure policy — no model)
# =========================================================================

def test_edf_ordering_priority_then_deadline_then_arrival():
    q = AdmissionQueue()
    a = _req(0, priority=0, deadline=5)
    b = _req(1, priority=2, deadline=50)
    c = _req(2, priority=2, deadline=10)
    d = _req(3, priority=2, deadline=10)     # same as c: arrival breaks tie
    for r in (a, b, c, d):
        assert q.offer(r, now=0) == (True, [], "")
    assert [q.pop_next().rid for _ in range(4)] == [2, 3, 1, 0]


def test_queue_without_metadata_is_fifo():
    """Deadline-free single-priority traffic degenerates to exact FIFO —
    the legacy engine behavior."""
    q = AdmissionQueue()
    for rid in range(5):
        q.offer(_req(rid), now=0)
    assert [q.pop_next().rid for _ in range(5)] == [0, 1, 2, 3, 4]


def test_bounded_queue_sheds_incoming():
    q = AdmissionQueue(AdmissionConfig(max_queue=2))
    q.offer(_req(0), 0)
    q.offer(_req(1), 0)
    admitted, shed, reason = q.offer(_req(2), 0)
    assert not admitted and shed[0].rid == 2 and "queue full" in reason
    assert len(q) == 2


def test_bounded_queue_displaces_less_urgent():
    q = AdmissionQueue(AdmissionConfig(max_queue=2))
    q.offer(_req(0, priority=1), 0)
    q.offer(_req(1, priority=0), 0)
    admitted, shed, reason = q.offer(_req(2, priority=3, deadline=9), 0)
    assert admitted and shed[0].rid == 1 and "displaced" in reason
    assert sorted(r.rid for r in q) == [0, 2]
    # an equal-urgency newcomer never bumps an older request
    admitted, shed, _ = q.offer(_req(3, priority=1), 0)
    assert not admitted and shed[0].rid == 3


def test_fifo_policy_never_displaces():
    q = AdmissionQueue(AdmissionConfig(max_queue=1, policy="fifo"))
    q.offer(_req(0), 0)
    admitted, shed, _ = q.offer(_req(1, priority=9, deadline=1), 0)
    assert not admitted and shed[0].rid == 1


def test_tenant_quota():
    q = AdmissionQueue(AdmissionConfig(tenant_quota=2))
    q.offer(_req(0, tenant="a"), 0)
    q.offer(_req(1, tenant="a"), 0)
    admitted, shed, reason = q.offer(_req(2, tenant="a"), 0)
    assert not admitted and "quota" in reason
    admitted, _, _ = q.offer(_req(3, tenant="b"), 0)   # other tenant is fine
    assert admitted


def test_queue_expiry_passed_and_infeasible():
    q = AdmissionQueue()
    q.offer(_req(0, deadline=3, max_tokens=2), 0)     # passed at now=4
    q.offer(_req(1, deadline=10, max_tokens=9), 0)    # infeasible at now=4
    q.offer(_req(2, deadline=10, max_tokens=2), 0)    # still fine
    q.offer(_req(3), 0)                               # no deadline
    expired = q.expire(now=4)
    assert {r.rid for r, _ in expired} == {0, 1}
    reasons = {r.rid: why for r, why in expired}
    assert "passed" in reasons[0] and "infeasible" in reasons[1]
    assert sorted(r.rid for r in q) == [2, 3]


def test_feasible_and_critical_windows():
    r = _req(0, deadline=10, max_tokens=4)            # needs 4 ticks
    assert feasible(r, now=6) and not feasible(r, now=7)
    assert not deadline_critical(r, now=4)            # plenty of slack
    assert deadline_critical(r, now=5)                # need+1 window
    assert deadline_critical(r, now=6)                # last feasible tick
    assert not deadline_critical(r, now=7)            # doomed → expiry's job
    assert not deadline_critical(_req(1), now=0)      # no deadline


def test_admission_config_validation():
    with pytest.raises(ValueError, match="policy"):
        AdmissionConfig(policy="lifo")
    with pytest.raises(ValueError, match="max_queue"):
        AdmissionConfig(max_queue=0)
    with pytest.raises(ValueError, match="tenant_quota"):
        AdmissionConfig(tenant_quota=0)


# =========================================================================
# Engine: admission-time rejections (satellite regressions)
# =========================================================================

def test_oversized_prompt_rejected_at_admission(small_model):
    """Regression: a prompt with len(prompt) >= max_len used to be spliced
    anyway with pos[slot] out of bounds (silent KV overflow).  It must be
    rejected terminally at admission with a diagnosis."""
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=16)
    engine.submit(Request(rid=0, prompt=list(range(1, 17)), max_tokens=4))
    done = engine.run()
    assert len(done) == 1
    assert done[0].state is RequestState.FAILED
    assert "KV capacity" in done[0].error
    assert all(s is None for s in engine.slots)       # never took a slot


def test_prompt_at_capacity_boundary_still_serves(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=16)
    engine.submit(Request(rid=0, prompt=list(range(1, 16)), max_tokens=4))
    done = engine.run()
    assert done[0].state is RequestState.DONE
    assert len(done[0].output) >= 1


def test_tick_budget_exhaustion_strands_nothing(small_model):
    """Regression: run(max_ticks) used to return silently with requests
    still PENDING/RUNNING.  Leftovers must be expired terminally."""
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=32)
    for rid in range(4):
        engine.submit(Request(rid=rid, prompt=[1 + rid, 2], max_tokens=8))
    done = engine.run(max_ticks=3)
    assert len(done) == 4                              # nothing vanished
    assert all(r.state in TERMINAL_STATES for r in done)
    exhausted = [r for r in done if r.error == "tick budget exhausted"]
    assert len(exhausted) >= 3                         # 1 running + queued
    assert all(s is None for s in engine.slots)
    assert len(engine.admission) == 0


# =========================================================================
# Engine: shed / expire / preempt under the tick clock
# =========================================================================

def test_overload_sheds_with_provenance(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(
        model, params, max_slots=1, max_len=32,
        admission=AdmissionConfig(max_queue=2, tenant_quota=2))
    reqs = [Request(rid=rid, prompt=[1 + rid, 2], max_tokens=3,
                    tenant=f"t{rid % 2}") for rid in range(6)]
    for r in reqs:
        engine.submit(r)
    done = {r.rid: r for r in engine.run()}
    assert len(done) == 6
    shed = [r for r in done.values() if r.state is RequestState.SHED]
    assert len(shed) == 4                      # burst: only 2 fit the queue
    assert all(r.error for r in shed)
    assert all(done[rid].state is RequestState.DONE for rid in (0, 1))
    assert engine.fault_stats["shed_requests"] == 4
    by_tenant = engine.fault_stats["by_tenant"]
    assert sum(t["shed"] for t in by_tenant.values()) == 4
    assert sum(t["submitted"] for t in by_tenant.values()) == 6


def test_running_request_expires_at_deadline(small_model):
    """With queued-expiry disabled, a doomed request reaches a slot and is
    evicted mid-decode the tick its deadline passes (the running rung of
    the expiry ladder — with the default config the queued sweep catches
    doomed requests before they ever occupy a slot)."""
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=32,
                             admission=AdmissionConfig(expire_queued=False))
    engine.submit(Request(rid=0, prompt=[1, 2, 3], max_tokens=10, ttl=4))
    done = engine.run()
    assert done[0].state is RequestState.EXPIRED
    assert "slot evicted" in done[0].error
    # partial progress retained: prefill token + decode ticks 2..4
    assert 1 <= len(done[0].output) < 10
    assert engine.fault_stats["expired_requests"] == 1


def test_queued_doomed_request_expires_early(small_model):
    """A queued request whose remaining slack is below its service time is
    expired immediately (doomed — every token would be late) instead of
    wasting a slot."""
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=32)
    engine.submit(Request(rid=0, prompt=[1, 2], max_tokens=6))   # hogs slot
    engine.submit(Request(rid=1, prompt=[3, 4], max_tokens=6, ttl=3))
    done = {r.rid: r for r in engine.run()}
    assert done[0].state is RequestState.DONE
    assert done[1].state is RequestState.EXPIRED
    assert "infeasible" in done[1].error
    assert done[1].output == []                # never reached a slot


def test_stale_deadline_expires_as_passed(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=32)
    engine.submit(Request(rid=0, prompt=[1, 2], max_tokens=2))
    engine.run()                               # advances the tick clock
    engine.submit(Request(rid=1, prompt=[3, 4], max_tokens=2, deadline=1))
    done = engine.run()
    assert done[0].state is RequestState.EXPIRED
    assert "passed in queue" in done[0].error


def test_priority_preemption_and_resume(small_model):
    """A deadline-critical high-priority arrival preempts the running
    low-priority request; the victim resumes later (prompt + partial
    output replayed) and its final output equals an uninterrupted run."""
    cfg, model, params = small_model

    def run_with_prod(submit_prod):
        engine = InferenceEngine(model, params, max_slots=1, max_len=32)
        batch = Request(rid=0, prompt=[1, 2, 3], max_tokens=8, priority=0)
        engine.submit(batch)
        engine.step()                          # batch takes the only slot
        prod = None
        if submit_prod:
            prod = Request(rid=1, prompt=[4, 5, 6], max_tokens=4,
                           priority=2, ttl=6)
            engine.submit(prod)
        engine.run()
        return engine, batch, prod

    _, undisturbed, _ = run_with_prod(False)
    engine, batch, prod = run_with_prod(True)
    assert prod.state is RequestState.DONE
    assert prod.finish_tick <= prod.deadline   # preemption saved the SLO
    assert batch.state is RequestState.DONE
    assert batch.preemptions == 1
    assert batch.output == undisturbed.output  # resume == uninterrupted
    assert engine.fault_stats["preemptions"] == 1
    assert engine.fault_stats["by_tenant"]["default"]["preempted"] == 1


def test_no_preemption_of_equal_or_higher_priority(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=32)
    first = Request(rid=0, prompt=[1, 2, 3], max_tokens=8, priority=2)
    engine.submit(first)
    engine.step()
    engine.submit(Request(rid=1, prompt=[4, 5], max_tokens=4, priority=2,
                          ttl=5))
    done = {r.rid: r for r in engine.run()}
    assert engine.fault_stats["preemptions"] == 0
    assert done[0].state is RequestState.DONE and done[0].preemptions == 0


# =========================================================================
# Engine lifecycle: drain / health
# =========================================================================

def test_drain_closes_admission_and_finishes_inflight(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=2, max_len=32)
    engine.submit(Request(rid=0, prompt=[1, 2], max_tokens=3))
    done = engine.drain()
    assert done[0].state is RequestState.DONE
    assert not engine.accepting
    late = Request(rid=1, prompt=[3, 4], max_tokens=3)
    engine.submit(late)
    assert late.state is RequestState.SHED
    assert "draining" in late.error
    assert late in engine.run()                # still reported, not lost


def test_health_snapshot(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=2, max_len=32)
    engine.submit(Request(rid=0, prompt=[1, 2], max_tokens=6, tenant="a"))
    engine.submit(Request(rid=1, prompt=[3, 4], max_tokens=6, tenant="b"))
    engine.submit(Request(rid=2, prompt=[5, 6], max_tokens=6, tenant="b"))
    engine.step()                              # admit rid=0
    engine.step()                              # admit rid=1
    h = engine.health()
    assert h["tick"] == 2 and h["accepting"]
    assert h["running"] == 2 and h["free_slots"] == 0
    assert h["queued"] == 1 and h["queued_by_tenant"] == {"b": 1}
    assert h["compiled_decode"] is True
    assert h["fault_stats"]["by_tenant"]["b"]["submitted"] == 2
    # snapshot is detached — mutating it must not touch live counters
    h["fault_stats"]["shed_requests"] = 99
    assert engine.fault_stats["shed_requests"] == 0


def test_tenant_sessions_collect_isolated_provenance(small_model):
    from repro.core import Session

    cfg, model, params = small_model
    sessions = {"a": Session(), "b": Session()}
    engine = InferenceEngine(
        model, params, max_slots=1, max_len=32,
        admission=AdmissionConfig(max_queue=1),
        tenant_sessions=sessions)
    engine.submit(Request(rid=0, prompt=[1, 2], max_tokens=3, tenant="a"))
    engine.submit(Request(rid=1, prompt=[3, 4], max_tokens=3, tenant="b"))
    engine.submit(Request(rid=2, prompt=[5, 6], max_tokens=3, tenant="b"))
    engine.run()
    # both of tenant b's sheds landed on b's guard_log ONLY (rid=0 filled
    # the bounded queue before any tick could admit it to a slot)
    assert len(sessions["a"].guard_log) == 0
    events = sessions["b"].guard_log.as_dicts()
    assert len(events) == 2
    assert all(e["site"] == "admission_enqueue" for e in events)
    assert all(e["action"] == "admit->shed" for e in events)


# =========================================================================
# Watchdog probation rung (satellite)
# =========================================================================

def test_watchdog_probation_retries_jitted_step(small_model):
    cfg, model, params = small_model

    def run(fault_plan, probation):
        engine = InferenceEngine(model, params, max_slots=1, max_len=32,
                                 fault_plan=fault_plan,
                                 watchdog_probation=probation)
        req = Request(rid=0, prompt=[1, 2, 3], max_tokens=8)
        engine.submit(req)
        engine.run()
        return engine, req

    _, clean = run(None, probation=2)
    plan = FaultPlan.single("decode_step", mode="raise", times=1)
    with pytest.warns(UserWarning, match="decode watchdog"):
        engine, req = run(plan, probation=2)
    assert engine._use_compiled is True        # probation un-latched
    assert engine.fault_stats["watchdog_fallbacks"] == 1
    assert engine.fault_stats["watchdog_probations"] == 1
    assert req.output == clean.output          # eager == jitted tokens


def test_watchdog_probation_relatches_on_persistent_fault(small_model):
    cfg, model, params = small_model
    plan = FaultPlan.single("decode_step", mode="raise", times=-1)
    engine = InferenceEngine(model, params, max_slots=1, max_len=32,
                             fault_plan=plan, watchdog_probation=2)
    req = Request(rid=0, prompt=[1, 2, 3], max_tokens=10)
    engine.submit(req)
    with pytest.warns(UserWarning, match="decode watchdog"):
        done = engine.run()
    assert done[0].state is RequestState.DONE  # still drained eagerly
    assert engine.fault_stats["watchdog_fallbacks"] >= 2   # re-latched
    assert engine.fault_stats["watchdog_probations"] >= 1


def test_watchdog_probation_zero_latches_forever(small_model):
    cfg, model, params = small_model
    plan = FaultPlan.single("decode_step", mode="raise", times=1)
    engine = InferenceEngine(model, params, max_slots=1, max_len=32,
                             fault_plan=plan, watchdog_probation=0)
    engine.submit(Request(rid=0, prompt=[1, 2, 3], max_tokens=10))
    with pytest.warns(UserWarning, match="decode watchdog"):
        engine.run()
    assert engine._use_compiled is False       # PR 6 behavior preserved
    assert engine.fault_stats["watchdog_probations"] == 0


# =========================================================================
# Multi-slot admission determinism (satellite)
# =========================================================================

def _overload_trace():
    specs = []
    for rid in range(9):
        specs.append(dict(rid=rid, prompt=[1 + rid, 2, 3], max_tokens=4,
                          tenant=f"t{rid % 3}", priority=rid % 3,
                          ttl=8 + 2 * (rid % 4) if rid % 2 else None))
    return specs


def _run_overload(model, params, compiled=True):
    engine = InferenceEngine(
        model, params, max_slots=2, max_len=32,
        admission=AdmissionConfig(max_queue=4, tenant_quota=3),
        watchdog_probation=0)
    if not compiled:
        engine._use_compiled = False           # force the eager decode rung
    reqs = [Request(**spec) for spec in _overload_trace()]
    for i, r in enumerate(reqs):
        engine.submit(r)
        if i % 3 == 2:
            engine.step()                      # staggered burst
    engine.run(max_ticks=64)
    decisions = [(r.rid, r.state.value, tuple(r.output), r.error,
                  r.preemptions, r.finish_tick) for r in reqs]
    return engine, decisions


def test_admission_determinism_compiled_and_eager(small_model):
    """Same (seed, arrival order, deadlines) → byte-identical outputs and
    identical shed/expire/preempt decisions, replayed twice under the
    compiled decode step and twice under the eager one."""
    cfg, model, params = small_model
    e1, d1 = _run_overload(model, params, compiled=True)
    e2, d2 = _run_overload(model, params, compiled=True)
    assert d1 == d2                            # replay is bit-identical
    e3, d3 = _run_overload(model, params, compiled=False)
    e4, d4 = _run_overload(model, params, compiled=False)
    assert d3 == d4
    assert d1 == d3                            # compiled == eager decisions
    s1, s3 = e1.fault_stats, e3.fault_stats
    for key in ("shed_requests", "expired_requests", "preemptions"):
        assert s1[key] == s3[key]
    assert s1["by_tenant"] == s3["by_tenant"]


def test_overload_trace_all_terminal_with_fault_sites_armed(small_model):
    """Acceptance: overload trace × all three admission fault sites armed →
    zero crashes, every request terminal, queue + slots drained."""
    from repro.runtime import faults

    cfg, model, params = small_model
    plan = FaultPlan.parse(
        "admission_enqueue:raise:2;slot_preempt:raise:1;deadline_check:raise:3")
    with faults.activate(plan):
        engine, decisions = _run_overload(model, params)
    assert all(state in {s.value for s in TERMINAL_STATES}
               for _, state, *_ in decisions)
    assert len(engine.admission) == 0
    assert all(s is None for s in engine.slots)
    assert engine.fault_stats["admission_faults"] == 2
    assert engine.fault_stats["deadline_faults"] == 3


# =========================================================================
# Goodput vs FIFO (bench acceptance, shrunk)
# =========================================================================

def test_admission_goodput_beats_fifo_baseline(small_model):
    from benchmarks.bench_serving import build_trace, measure

    cfg, model, params = small_model
    trace = build_trace(n=12, seed=7)
    fifo = InferenceEngine(
        model, params, max_slots=2, max_len=64,
        admission=AdmissionConfig(policy="fifo", preemption=False,
                                  expire_queued=False, expire_running=False))
    fifo_row = measure(fifo, trace, "fifo")
    edf = InferenceEngine(
        model, params, max_slots=2, max_len=64,
        admission=AdmissionConfig(max_queue=6, tenant_quota=5))
    edf_row = measure(edf, trace, "edf")
    assert edf_row["goodput_tok_per_tick"] > fifo_row["goodput_tok_per_tick"]
    assert edf_row["deadline_miss_rate"] <= fifo_row["deadline_miss_rate"]


def test_ttl_resolves_to_absolute_deadline_at_submit(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=32)
    engine.submit(Request(rid=0, prompt=[1, 2], max_tokens=2))
    engine.run()
    tick = engine.tick
    req = Request(rid=1, prompt=[3, 4], max_tokens=2, ttl=10)
    engine.submit(req)
    assert req.deadline == tick + 10 and req.submit_tick == tick
