"""Hypothesis property tests over the scheduling system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    SimConfig,
    allocate_streams,
    allocate_streams_nimble,
    build_waves,
    count_syncs,
    schedule,
    sequential_makespan,
    simulate_plan,
)
from repro.core.launch_order import ORDER_POLICIES, validate_order
from repro.core.profiler import ModelProfiler, V5E
from repro.core.stream_alloc import validate_plan

from conftest import random_dag


dag_strategy = st.builds(
    lambda seed, n, p: random_dag(np.random.default_rng(seed), n, p),
    st.integers(0, 10_000), st.integers(1, 40),
    st.floats(0.05, 0.9),
)


@settings(max_examples=60, deadline=None)
@given(dag_strategy)
def test_stream_plans_valid(g):
    for alloc in (allocate_streams, allocate_streams_nimble):
        plan = alloc(g)
        validate_plan(g, plan)


@settings(max_examples=60, deadline=None)
@given(dag_strategy, st.sampled_from(list(ORDER_POLICIES)))
def test_orders_topological(g, policy):
    profiles = ModelProfiler(V5E).profile(g)
    validate_order(g, ORDER_POLICIES[policy](g, profiles))


@settings(max_examples=40, deadline=None)
@given(dag_strategy)
def test_waves_partition_and_respect_deps(g):
    plan = schedule(g, "opara", "opara")
    seen = [op for w in plan.waves.waves for op in w.op_ids]
    assert sorted(seen) == sorted(g.nodes)
    wave_of = {op: w.index for w in plan.waves.waves for op in w.op_ids}
    for node in g:
        for p in node.inputs:
            assert wave_of[p] < wave_of[node.op_id]
    # ops in the same wave are mutually independent (no edges within a wave)
    for w in plan.waves.waves:
        ops = set(w.op_ids)
        for op in w.op_ids:
            assert not (set(g.nodes[op].inputs) & ops)


@settings(max_examples=30, deadline=None)
@given(dag_strategy)
def test_simulated_makespan_bounds(g):
    plan = schedule(g, "opara", "opara")
    cfg = SimConfig(sync_us=0.0, interference_penalty=0.0)
    res = simulate_plan(plan, cfg)
    seq = sequential_makespan(g, plan.profiles, cfg)
    durations = {i: plan.profiles[i].est_us for i in g.nodes}
    assert res.makespan_us <= seq * (1 + 1e-9) + 1e-6
    assert res.makespan_us >= g.critical_path_cost(durations) - 1e-6


@settings(max_examples=40, deadline=None)
@given(dag_strategy)
def test_nimble_never_more_streams_than_opara(g):
    """Nimble computes a MINIMUM path cover; Opara trades stream count for
    fewer syncs — so Nimble's stream count is a lower bound."""
    assert allocate_streams_nimble(g).n_streams <= allocate_streams(g).n_streams


@settings(max_examples=40, deadline=None)
@given(dag_strategy)
def test_sync_count_upper_bound(g):
    plan = allocate_streams(g)
    n_edges = sum(len(set(n.inputs)) for n in g)
    assert 0 <= count_syncs(g, plan) <= n_edges
